"""Discrete-event executor for RT CPU–bus–accelerator task sets.

This is the container-side stand-in for the paper's real-GPU experiment
(Figs. 12–13): it *executes* task sets under the RTGPU runtime rules —

  * CPU: preemptive fixed-priority (one core),
  * bus: non-preemptive fixed-priority (one PCIe-like channel),
  * accelerator: federated — every task owns 2·GN_i dedicated virtual SMs
    (chip-slice interleave lanes), so GPU segments start immediately after
    their copy-in completes (no contention by construction),

with per-job segment durations sampled from [lo, hi] (worst-case model:
lo == hi).  Observed response times validate the analysis bounds:
tests assert  observed R ≤ analytic R̂  for admitted sets.

Two entry points:
  * :func:`simulate` — fixed task set over a horizon (the seed behavior);
  * :func:`simulate_churn` — dynamic membership: an admit/release event
    trace is fed through a :class:`repro.sched.DynamicController`, slices
    are reclaimed only at job boundaries (mode-change protocol), and every
    completed job is checked against the analytic bound certified by the
    admission epoch it was released in.

Both record into an optional :class:`repro.sched.EventTrace` (releases,
CPU preemptions, completions, deadline misses) for Chrome-trace export.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Optional, Sequence

import numpy as np

from repro.core import ChurnEvent, RTTask, SegmentKind, TaskSet
from repro.sched import DynamicController, EventTrace

__all__ = ["SimResult", "simulate", "ChurnSimResult", "simulate_churn"]

_EPS = 1e-9


@dataclasses.dataclass
class SimResult:
    responses: list[list[float]]          # per task, per completed job
    misses: list[int]                     # per task deadline misses
    jobs: list[int]                       # per task completed jobs

    @property
    def any_miss(self) -> bool:
        return any(m > 0 for m in self.misses)

    def max_response(self, i: int) -> float:
        return max(self.responses[i]) if self.responses[i] else 0.0


@dataclasses.dataclass
class _Job:
    task_id: int
    release: float
    deadline_abs: float
    seg_idx: int = 0
    remaining: float = 0.0          # remaining time of the current segment
    durations: Optional[list] = None
    done: bool = False


def _sample_durations(
    task: RTTask, alloc_vsm: int, rng, worst_case: bool = False
) -> list[float]:
    """One duration per chain segment, honoring [lo, hi] bounds and
    Lemma 5.1 for accelerator segments.  ``worst_case`` pins every segment
    to its upper bound (the Fig. 12 WCET execution model)."""
    out = []
    for kind, idx in task.chain():
        if kind is SegmentKind.CPU:
            lo, hi = task.cpu_lo[idx], task.cpu_hi[idx]
        elif kind is SegmentKind.MEM:
            lo, hi = task.mem_lo[idx], task.mem_hi[idx]
        else:
            lo, hi = task.gpu[idx].response_bounds(alloc_vsm)
        if worst_case or hi <= lo:
            out.append(hi)
        else:
            out.append(float(rng.uniform(lo, hi)))
    return out


def simulate(
    taskset: TaskSet,
    alloc: list[int],
    horizon: float,
    seed: int = 0,
    release_jitter: bool = True,
    worst_case: bool = False,
    trace: Optional[EventTrace] = None,
) -> SimResult:
    """Run the federated RT executor for ``horizon`` time units.

    Priority = taskset order (0 highest).  Sporadic releases: period T_i
    plus optional random inter-arrival slack (sporadic ≥ T)."""
    n = len(taskset)
    rng = np.random.default_rng(seed)
    chains = [t.chain() for t in taskset]
    names = [t.name or f"task{i}" for i, t in enumerate(taskset)]

    releases: list[float] = []
    for i, t in enumerate(taskset):
        releases.append(float(rng.uniform(0, t.period)) if release_jitter else 0.0)

    jobs: list[Optional[_Job]] = [None] * n  # at most one active job per task
    responses: list[list[float]] = [[] for _ in range(n)]
    misses = [0] * n
    completed = [0] * n

    now = 0.0
    bus_running: Optional[int] = None  # task id holding the bus (non-preempt)
    last_cpu_owner: Optional[int] = None

    def seg_kind(i: int) -> Optional[SegmentKind]:
        j = jobs[i]
        if j is None or j.done:
            return None
        return chains[i][j.seg_idx][0]

    while now < horizon:
        # release new jobs
        for i, t in enumerate(taskset):
            if jobs[i] is None and releases[i] <= now + _EPS:
                j = _Job(
                    task_id=i,
                    release=releases[i],
                    deadline_abs=releases[i] + t.deadline,
                    durations=_sample_durations(t, 2 * alloc[i], rng, worst_case),
                )
                j.remaining = j.durations[0]
                jobs[i] = j
                if trace is not None:
                    trace.record(now, "release", names[i],
                                 deadline=j.deadline_abs)

        # pick CPU owner: highest-priority ready CPU segment (preemptive)
        cpu_owner = next(
            (i for i in range(n) if seg_kind(i) is SegmentKind.CPU), None
        )
        if (
            trace is not None
            and last_cpu_owner is not None
            and cpu_owner != last_cpu_owner
            and seg_kind(last_cpu_owner) is SegmentKind.CPU
            and jobs[last_cpu_owner].remaining > _EPS
        ):
            trace.record(now, "preempt", names[last_cpu_owner],
                         by=names[cpu_owner] if cpu_owner is not None else "")
        last_cpu_owner = cpu_owner
        # bus owner: keep non-preemptive holder; else highest-priority waiter
        if bus_running is not None and seg_kind(bus_running) is not SegmentKind.MEM:
            bus_running = None
        if bus_running is None:
            bus_running = next(
                (i for i in range(n) if seg_kind(i) is SegmentKind.MEM), None
            )

        # running set: cpu owner, bus owner, every GPU segment (dedicated)
        running = set()
        if cpu_owner is not None:
            running.add(cpu_owner)
        if bus_running is not None:
            running.add(bus_running)
        for i in range(n):
            if seg_kind(i) is SegmentKind.GPU:
                running.add(i)

        # next event time: earliest completion or next release
        dt = math.inf
        for i in running:
            dt = min(dt, jobs[i].remaining)
        for i in range(n):
            if jobs[i] is None:
                dt = min(dt, releases[i] - now)
        if not math.isfinite(dt):
            break
        dt = max(dt, 0.0)
        step_end = min(now + dt, horizon)
        dt = step_end - now

        for i in running:
            jobs[i].remaining -= dt
        now = step_end

        # process completions
        for i in list(running):
            j = jobs[i]
            if j.remaining <= _EPS:
                if chains[i][j.seg_idx][0] is SegmentKind.MEM and bus_running == i:
                    bus_running = None
                j.seg_idx += 1
                if j.seg_idx >= len(chains[i]):
                    resp = now - j.release
                    responses[i].append(resp)
                    completed[i] += 1
                    if trace is not None:
                        trace.record(now, "complete", names[i],
                                     response=resp)
                    if resp > taskset[i].deadline + 1e-6:
                        misses[i] += 1
                        if trace is not None:
                            trace.record(
                                now, "miss", names[i],
                                overshoot=resp - taskset[i].deadline,
                            )
                    # next sporadic release
                    gap = 0.0
                    if release_jitter:
                        gap = float(rng.uniform(0, 0.2 * taskset[i].period))
                    releases[i] = j.release + taskset[i].period + gap
                    if releases[i] < now:
                        releases[i] = now
                    jobs[i] = None
                else:
                    j.remaining = j.durations[j.seg_idx]
    return SimResult(responses=responses, misses=misses, jobs=completed)


# ---- dynamic-membership executor (online scheduler validation) --------------


@dataclasses.dataclass
class ChurnSimResult:
    """Per-service outcome of a churn-trace run.

    ``responses[name][k]`` and ``bounds[name][k]`` pair each completed
    job's observed response with the analytic R̂ certified by the admission
    epoch the job was released in — the validation invariant is
    ``observed ≤ bound`` for every job, in every epoch, across the trace."""

    responses: dict[str, list[float]]
    bounds: dict[str, list[float]]
    misses: dict[str, int]
    jobs: dict[str, int]
    admitted: list[str]
    rejected: list[str]

    @property
    def any_miss(self) -> bool:
        return any(m > 0 for m in self.misses.values())

    def bound_violations(self, eps: float = 1e-6) -> list[str]:
        out = []
        for name, rs in self.responses.items():
            for r, b in zip(rs, self.bounds[name]):
                if r > b + eps:
                    out.append(f"{name}: observed {r:.3f} > bound {b:.3f}")
        return out

    @property
    def total_jobs(self) -> int:
        return sum(self.jobs.values())


@dataclasses.dataclass
class _ChurnJob:
    name: str
    release: float
    deadline_abs: float
    chain: list
    durations: list
    bound: float                  # analytic R̂ at release epoch
    seg_idx: int = 0
    remaining: float = 0.0


def simulate_churn(
    events: Sequence[ChurnEvent],
    gn_total: int,
    horizon: float,
    seed: int = 0,
    release_jitter: bool = True,
    worst_case: bool = False,
    tightened: bool = True,
    allow_realloc: bool = True,
    controller: Optional[DynamicController] = None,
    trace: Optional[EventTrace] = None,
) -> ChurnSimResult:
    """Execute an admit/release churn trace under the online scheduler.

    Every ``admit`` event goes through the controller's transitional
    analysis; rejected services never run.  A ``release`` event marks the
    service departing — its job in flight finishes and only then does
    :meth:`DynamicController.job_boundary` reclaim the slices (the
    mode-change protocol).  Each job samples durations with the task
    parameters and slice count *committed at its release*, and is checked
    against the analytic bound of that epoch."""
    if controller is None:
        controller = DynamicController(
            gn_total,
            tightened=tightened,
            transition="boundary",
            allow_realloc=allow_realloc,
            trace=trace,
        )
    if controller.transition != "boundary":
        # an instant controller reclaims mid-job, leaving the sim's active
        # map pointing at entries the controller no longer knows
        raise ValueError(
            "simulate_churn requires a boundary-transition controller "
            f"(got transition={controller.transition!r})"
        )
    rng = np.random.default_rng(seed)
    pending = sorted(events, key=lambda e: (e.time, e.name))
    ev_idx = 0

    active: dict[str, Optional[_ChurnJob]] = {}   # resident -> job in flight
    next_release: dict[str, float] = {}
    responses: dict[str, list[float]] = {}
    bounds: dict[str, list[float]] = {}
    misses: dict[str, int] = {}
    jobs_done: dict[str, int] = {}
    admitted: list[str] = []
    rejected: list[str] = []

    now = 0.0
    bus_running: Optional[str] = None
    last_cpu_owner: Optional[str] = None

    def seg_kind(name: str) -> Optional[SegmentKind]:
        j = active.get(name)
        if j is None:
            return None
        return j.chain[j.seg_idx][0]

    def finish_boundary(name: str) -> None:
        """Job boundary for ``name``: reclaim if departing, else commit
        staged mode changes; drop reclaimed services from the active map."""
        if controller.job_boundary(name, t=now) == "reclaimed":
            active.pop(name, None)
            next_release.pop(name, None)

    while now < horizon - _EPS:
        # 1. churn events due now
        while ev_idx < len(pending) and pending[ev_idx].time <= now + _EPS:
            ev = pending[ev_idx]
            ev_idx += 1
            if ev.kind == "admit":
                dec = controller.admit(ev.task, t=now)
                if dec.admitted:
                    admitted.append(ev.name)
                    active[ev.name] = None
                    next_release[ev.name] = now
                    # setdefault: a re-admission of a departed name must
                    # extend its history, not erase the first residency
                    responses.setdefault(ev.name, [])
                    bounds.setdefault(ev.name, [])
                    misses.setdefault(ev.name, 0)
                    jobs_done.setdefault(ev.name, 0)
                    # a job spanning the reconfiguration sees the arrival's
                    # interference: lift its bound to the new epoch's R̂
                    # (certified over the transitional set, so valid for
                    # jobs of either epoch)
                    for n2, j2 in active.items():
                        if j2 is not None:
                            j2.bound = max(j2.bound, controller.bound(n2))
                else:
                    rejected.append(ev.name)
            elif ev.kind == "release":
                if controller.release(ev.name, t=now) and active.get(ev.name) is None:
                    finish_boundary(ev.name)   # idle: reclaim immediately
            else:
                raise ValueError(f"unknown churn event kind {ev.kind!r}")

        # 2. job releases (departing services release no new jobs)
        for name in list(active):
            if (
                active[name] is None
                and not controller.is_departing(name)
                and next_release[name] <= now + _EPS
            ):
                task = controller.task(name)
                vsm = 2 * controller.allocation[name]
                j = _ChurnJob(
                    name=name,
                    release=next_release[name],
                    deadline_abs=next_release[name] + task.deadline,
                    chain=task.chain(),
                    durations=_sample_durations(task, vsm, rng, worst_case),
                    bound=controller.bound(name),
                )
                j.remaining = j.durations[0]
                active[name] = j
                if trace is not None:
                    trace.record(now, "release", name, deadline=j.deadline_abs)

        # 3. arbitration under the controller's current priority order
        prio = {n: i for i, n in enumerate(controller.order())}
        ready_cpu = sorted(
            (n for n in active if seg_kind(n) is SegmentKind.CPU),
            key=lambda n: prio.get(n, len(prio)),
        )
        cpu_owner = ready_cpu[0] if ready_cpu else None
        if (
            trace is not None
            and last_cpu_owner is not None
            and cpu_owner != last_cpu_owner
            and seg_kind(last_cpu_owner) is SegmentKind.CPU
            and active[last_cpu_owner].remaining > _EPS
        ):
            trace.record(now, "preempt", last_cpu_owner, by=cpu_owner or "")
        last_cpu_owner = cpu_owner

        if bus_running is not None and seg_kind(bus_running) is not SegmentKind.MEM:
            bus_running = None
        if bus_running is None:
            ready_mem = sorted(
                (n for n in active if seg_kind(n) is SegmentKind.MEM),
                key=lambda n: prio.get(n, len(prio)),
            )
            bus_running = ready_mem[0] if ready_mem else None

        running = set()
        if cpu_owner is not None:
            running.add(cpu_owner)
        if bus_running is not None:
            running.add(bus_running)
        for name in active:
            if seg_kind(name) is SegmentKind.GPU:
                running.add(name)

        # 4. next event time: completion, release, churn event, or horizon
        dt = math.inf
        for name in running:
            dt = min(dt, active[name].remaining)
        for name in active:
            if active[name] is None and not controller.is_departing(name):
                dt = min(dt, next_release[name] - now)
        if ev_idx < len(pending):
            dt = min(dt, pending[ev_idx].time - now)
        if not math.isfinite(dt):
            break
        dt = max(dt, 0.0)
        step_end = min(now + dt, horizon)
        dt = step_end - now

        for name in running:
            active[name].remaining -= dt
        now = step_end

        # 5. completions
        for name in list(running):
            j = active.get(name)
            if j is None or j.remaining > _EPS:
                continue
            if j.chain[j.seg_idx][0] is SegmentKind.MEM and bus_running == name:
                bus_running = None
            j.seg_idx += 1
            if j.seg_idx < len(j.chain):
                j.remaining = j.durations[j.seg_idx]
                continue
            # job done
            resp = now - j.release
            responses[name].append(resp)
            bounds[name].append(j.bound)
            jobs_done[name] += 1
            deadline = j.deadline_abs - j.release
            if trace is not None:
                trace.record(now, "complete", name, response=resp,
                             bound=j.bound)
            if resp > deadline + 1e-6:
                misses[name] += 1
                if trace is not None:
                    trace.record(now, "miss", name,
                                 overshoot=resp - deadline)
            active[name] = None
            finish_boundary(name)          # reclaim / commit staged changes
            if name in active:             # still resident: next sporadic gap
                task = controller.task(name)
                gap = 0.0
                if release_jitter:
                    gap = float(rng.uniform(0, 0.2 * task.period))
                next_release[name] = max(j.release + task.period + gap, now)

    return ChurnSimResult(
        responses=responses,
        bounds=bounds,
        misses=misses,
        jobs=jobs_done,
        admitted=admitted,
        rejected=rejected,
    )
