"""Discrete-event executor for RT CPU–bus–accelerator task sets.

This is the container-side stand-in for the paper's real-GPU experiment
(Figs. 12–13): it *executes* task sets under the RTGPU runtime rules —

  * CPU: preemptive fixed-priority (one core),
  * bus: non-preemptive fixed-priority (one PCIe-like channel),
  * accelerator: federated — every task owns 2·GN_i dedicated virtual SMs
    (chip-slice interleave lanes), so GPU segments start immediately after
    their copy-in completes (no contention by construction),

with per-job segment durations sampled from [lo, hi] (worst-case model:
lo == hi).  Observed response times validate the analysis bounds:
tests assert  observed R ≤ analytic R̂  for admitted sets.

Both entry points are thin policies over the one shared
:class:`repro.runtime.engine.DiscreteEventEngine` (the arbitration loop
lives there, exactly once):

  * :func:`simulate` — :class:`_FixedTaskSetPolicy`: a frozen task set
    over a horizon, priority = taskset order (the seed behavior);
  * :func:`simulate_churn` — :class:`_ChurnPolicy`: dynamic membership —
    an admit/release event trace is fed through a
    :class:`repro.sched.DynamicController`, slices are reclaimed only at
    job boundaries (mode-change protocol), and every completed job is
    checked against the analytic bound certified by the admission epoch it
    was released in;
  * :func:`simulate_fleet` — :class:`_FleetChurnPolicy`: multi-host churn —
    arrivals are routed by a :class:`repro.sched.CapacityBroker` across N
    hosts (one CPU + bus + slice-pool resource lane each, lockstepped in
    one engine), departures trigger imbalance migrations executed through
    the mode-change protocol, and the same observed-R ≤ certified-R̂ check
    runs per job on whichever host it executed; an optional ``elastic``
    schedule grows (``add_host``) and shrinks (drain-then-retire) the
    fleet mid-run.

All record into an optional :class:`repro.sched.EventTrace` (releases,
CPU preemptions, completions, deadline misses — host-tagged in the fleet
case); the golden corpus under ``tests/golden/`` pins their observable
behavior event by event.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Optional, Sequence

import numpy as np

from repro.core import ChurnEvent, RTTask, SegmentKind, TaskSet
from repro.sched import CapacityBroker, DynamicController, EventTrace

from .engine import DiscreteEventEngine, EngineJob, SchedulingPolicy

__all__ = [
    "SimResult",
    "simulate",
    "ChurnSimResult",
    "simulate_churn",
    "FleetSimResult",
    "simulate_fleet",
]

_EPS = 1e-9


@dataclasses.dataclass
class SimResult:
    responses: list[list[float]]          # per task, per completed job
    misses: list[int]                     # per task deadline misses
    jobs: list[int]                       # per task completed jobs

    @property
    def any_miss(self) -> bool:
        return any(m > 0 for m in self.misses)

    def max_response(self, i: int) -> float:
        return max(self.responses[i]) if self.responses[i] else 0.0


def _sample_durations(
    task: RTTask, alloc_vsm: int, rng, worst_case: bool = False
) -> list[float]:
    """One duration per chain segment, honoring [lo, hi] bounds and
    Lemma 5.1 for accelerator segments.  ``worst_case`` pins every segment
    to its upper bound (the Fig. 12 WCET execution model)."""
    out = []
    for kind, idx in task.chain():
        if kind is SegmentKind.CPU:
            lo, hi = task.cpu_lo[idx], task.cpu_hi[idx]
        elif kind is SegmentKind.MEM:
            lo, hi = task.mem_lo[idx], task.mem_hi[idx]
        else:
            lo, hi = task.gpu[idx].response_bounds(alloc_vsm)
        if worst_case or hi <= lo:
            out.append(hi)
        else:
            out.append(float(rng.uniform(lo, hi)))
    return out


class _FixedTaskSetPolicy(SchedulingPolicy):
    """Frozen membership: every task is resident for the whole run.

    Priority = taskset order (0 highest).  Sporadic releases: period T_i
    plus optional random inter-arrival slack (sporadic ≥ T).

    Incremental seam: membership is static (one shared group, priority =
    index order), so the only indexed structure is a release heap —
    entries are ``(release_time, task_index)``, lazily invalidated by
    comparing against ``self.releases`` (the single source of truth)."""

    incremental = True

    def __init__(
        self,
        taskset: TaskSet,
        alloc: list[int],
        rng: np.random.Generator,
        release_jitter: bool,
        worst_case: bool,
        preemption: str = "none",
        gpu_ctx_overhead: float = 0.0,
    ):
        self.taskset = taskset
        self.alloc = alloc
        self.rng = rng
        self.release_jitter = release_jitter
        self.worst_case = worst_case
        self._gpu_arbitration = (preemption, gpu_ctx_overhead)
        self.chains = [t.chain() for t in taskset]
        self.names = [t.name or f"task{i}" for i, t in enumerate(taskset)]
        self.releases = [
            float(rng.uniform(0, t.period)) if release_jitter else 0.0
            for t in taskset
        ]
        n = len(taskset)
        self.responses: list[list[float]] = [[] for _ in range(n)]
        self.misses = [0] * n
        self.completed = [0] * n

    def bind(self, engine: DiscreteEventEngine) -> None:
        super().bind(engine)
        engine.jobs = {i: None for i in range(len(self.taskset))}
        self._release_heap = [
            (self.releases[i], i) for i in range(len(self.taskset))
        ]
        heapq.heapify(self._release_heap)

    def _release_one(self, i: int) -> None:
        t = self.taskset[i]
        self.engine.start_job(i, EngineJob(
            release=self.releases[i],
            deadline_abs=self.releases[i] + t.deadline,
            chain=self.chains[i],
            durations=_sample_durations(
                t, 2 * self.alloc[i], self.rng, self.worst_case
            ),
        ))

    def release_jobs(self, now: float) -> None:
        eng = self.engine
        for i in range(len(self.taskset)):
            if eng.jobs[i] is None and self.releases[i] <= now + _EPS:
                self._release_one(i)

    def release_jobs_fast(self, now: float) -> None:
        # pop every due entry, drop the stale ones (an entry is live iff
        # it matches self.releases and the task is idle), then release in
        # index order — the same order the scan-based path produces, so
        # the RNG draw sequence is identical
        eng = self.engine
        heap = self._release_heap
        due = []
        while heap and heap[0][0] <= now + _EPS:
            t, i = heapq.heappop(heap)
            if self.releases[i] == t and eng.jobs[i] is None:
                due.append(i)
        due.sort()
        for i in due:
            self._release_one(i)

    def arbitration_order(self) -> list:
        return list(range(len(self.taskset)))

    def resource_groups(self) -> list:
        return [None]

    def sort_group(self, group, keys: list) -> list:
        keys.sort()
        return keys

    def next_external_time(self, now: float) -> float:
        return min(
            (self.releases[i] for i in range(len(self.taskset))
             if self.engine.jobs[i] is None),
            default=math.inf,
        )

    def next_external_time_fast(self, now: float) -> float:
        heap = self._release_heap
        while heap:
            t, i = heap[0]
            if self.releases[i] == t and self.engine.jobs[i] is None:
                return t
            heapq.heappop(heap)
        return math.inf

    def on_job_complete(self, key, job, now, response) -> None:
        eng = self.engine
        task = self.taskset[key]
        self.responses[key].append(response)
        self.completed[key] += 1
        eng.record("complete", key, response=response)
        if response > task.deadline + 1e-6:
            self.misses[key] += 1
            eng.record("miss", key, overshoot=response - task.deadline)
        # next sporadic release
        gap = (
            float(self.rng.uniform(0, 0.2 * task.period))
            if self.release_jitter else 0.0
        )
        self.releases[key] = max(job.release + task.period + gap, now)
        eng.jobs[key] = None
        heapq.heappush(self._release_heap, (self.releases[key], key))

    def display_name(self, key) -> str:
        return self.names[key]

    def gpu_arbitration(self) -> tuple[str, float]:
        return self._gpu_arbitration


def simulate(
    taskset: TaskSet,
    alloc: list[int],
    horizon: float,
    seed: int = 0,
    release_jitter: bool = True,
    worst_case: bool = False,
    trace: Optional[EventTrace] = None,
    preemption: str = "none",
    gpu_ctx_overhead: float = 0.0,
    engine_variant: Optional[str] = None,
) -> SimResult:
    """Run the RT executor for ``horizon`` time units.

    ``preemption`` selects the accelerator arbitration: ``"none"`` (the
    federated default — dedicated lanes, byte-identical to the seed
    behavior) or ``"priority"`` (preemptive priority-driven GPU context,
    ``gpu_ctx_overhead`` charged per preemption).

    ``engine_variant`` pins the event-loop implementation (``"indexed"``
    / ``"reference"``); ``None`` defers to ``REPRO_ENGINE`` (default
    indexed).  Both produce byte-identical traces."""
    policy = _FixedTaskSetPolicy(
        taskset, alloc, np.random.default_rng(seed), release_jitter,
        worst_case, preemption=preemption,
        gpu_ctx_overhead=gpu_ctx_overhead,
    )
    DiscreteEventEngine(policy, trace=trace,
                        variant=engine_variant).run(horizon)
    return SimResult(
        responses=policy.responses,
        misses=policy.misses,
        jobs=policy.completed,
    )


# ---- dynamic-membership executor (online scheduler validation) --------------


@dataclasses.dataclass
class ChurnSimResult:
    """Per-service outcome of a churn-trace run.

    ``responses[name][k]`` and ``bounds[name][k]`` pair each completed
    job's observed response with the analytic R̂ certified by the admission
    epoch the job was released in — the validation invariant is
    ``observed ≤ bound`` for every job, in every epoch, across the trace."""

    responses: dict[str, list[float]]
    bounds: dict[str, list[float]]
    misses: dict[str, int]
    jobs: dict[str, int]
    admitted: list[str]
    rejected: list[str]

    @property
    def any_miss(self) -> bool:
        return any(m > 0 for m in self.misses.values())

    def bound_violations(self, eps: float = 1e-6) -> list[str]:
        out = []
        for name, rs in self.responses.items():
            for r, b in zip(rs, self.bounds[name]):
                if r > b + eps:
                    out.append(f"{name}: observed {r:.3f} > bound {b:.3f}")
        return out

    @property
    def total_jobs(self) -> int:
        return sum(self.jobs.values())


class _ChurnPolicy(SchedulingPolicy):
    """Dynamic membership under the online controller.

    Every ``admit`` event goes through the controller's transitional
    analysis; rejected services never run.  A ``release`` event marks the
    service departing — its job in flight finishes and only then does
    :meth:`DynamicController.job_boundary` reclaim the slices (the
    mode-change protocol).  Each job samples durations with the task
    parameters and slice count *committed at its release*, and is checked
    against the analytic bound of that epoch.

    Incremental seam: one shared group; the priority order is the
    controller's deadline-sorted :meth:`~DynamicController.order`, so a
    capacity listener on the controller invalidates the engine's cached
    sort on every committed mutation (admit, reclaim, boundary commit,
    rate change).  Pending releases live in a lazily-invalidated heap of
    ``(time, membership_seq, name)`` — the membership sequence number
    reproduces the ``engine.jobs`` dict-insertion order the scan-based
    path releases same-time jobs in."""

    horizon_slack = _EPS
    incremental = True

    def __init__(
        self,
        events: Sequence[ChurnEvent],
        controller: DynamicController,
        rng: np.random.Generator,
        release_jitter: bool,
        worst_case: bool,
    ):
        self.controller = controller
        self.rng = rng
        self.release_jitter = release_jitter
        self.worst_case = worst_case
        self.pending = sorted(events, key=lambda e: (e.time, e.name))
        self.ev_idx = 0
        self.next_release: dict[str, float] = {}
        self._release_heap: list = []
        self._mseq: dict[str, int] = {}
        self._seq = 0
        self.responses: dict[str, list[float]] = {}
        self.bounds: dict[str, list[float]] = {}
        self.misses: dict[str, int] = {}
        self.jobs_done: dict[str, int] = {}
        self.admitted: list[str] = []
        self.rejected: list[str] = []

    def bind(self, engine: DiscreteEventEngine) -> None:
        super().bind(engine)
        # every committed controller mutation (admit, reclaim, boundary
        # commit, update_rate) can reshuffle the deadline-sorted priority
        # order — including out-of-band rate changes fired from trace
        # subscribers (BoundMonitor re-admission callbacks)
        self.controller.add_capacity_listener(self._on_capacity_change)

    def _on_capacity_change(self) -> None:
        self.order_changed(None)

    def gpu_arbitration(self) -> tuple[str, float]:
        # the runtime must execute the arbitration the controller certified
        pm = self.controller.preemption
        return (pm.mode, pm.ctx)

    def _finish_boundary(self, name: str, now: float) -> None:
        """Job boundary for ``name``: reclaim if departing, else commit
        staged mode changes; drop reclaimed services from membership."""
        if self.controller.job_boundary(name, t=now) == "reclaimed":
            self.engine.jobs.pop(name, None)
            self.next_release.pop(name, None)
            self._mseq.pop(name, None)
            self.membership_changed(name, added=False)

    def begin_step(self, now: float) -> None:
        eng = self.engine
        ctl = self.controller
        while (
            self.ev_idx < len(self.pending)
            and self.pending[self.ev_idx].time <= now + _EPS
        ):
            ev = self.pending[self.ev_idx]
            self.ev_idx += 1
            if ev.kind == "admit":
                dec = ctl.admit(ev.task, t=now)
                if dec.admitted:
                    self.admitted.append(ev.name)
                    eng.jobs[ev.name] = None
                    self.next_release[ev.name] = now
                    self._mseq[ev.name] = self._seq
                    self._seq += 1
                    self.membership_changed(ev.name, added=True)
                    heapq.heappush(
                        self._release_heap,
                        (now, self._mseq[ev.name], ev.name),
                    )
                    # setdefault: a re-admission of a departed name must
                    # extend its history, not erase the first residency
                    self.responses.setdefault(ev.name, [])
                    self.bounds.setdefault(ev.name, [])
                    self.misses.setdefault(ev.name, 0)
                    self.jobs_done.setdefault(ev.name, 0)
                    # a job spanning the reconfiguration sees the arrival's
                    # interference: lift its bound to the new epoch's R̂
                    # (certified over the transitional set, so valid for
                    # jobs of either epoch)
                    for name, job in eng.jobs.items():
                        if job is not None:
                            job.bound = max(job.bound, ctl.bound(name))
                else:
                    self.rejected.append(ev.name)
            elif ev.kind == "release":
                if ctl.release(ev.name, t=now) and eng.jobs.get(ev.name) is None:
                    self._finish_boundary(ev.name, now)  # idle: reclaim now
            else:
                raise ValueError(f"unknown churn event kind {ev.kind!r}")

    def _release_one(self, name: str) -> None:
        ctl = self.controller
        task = ctl.task(name)
        self.engine.start_job(name, EngineJob(
            release=self.next_release[name],
            deadline_abs=self.next_release[name] + task.deadline,
            chain=task.chain(),
            durations=_sample_durations(
                task, 2 * ctl.allocation[name], self.rng,
                self.worst_case,
            ),
            bound=ctl.bound(name),
        ))

    def release_jobs(self, now: float) -> None:
        eng = self.engine
        ctl = self.controller
        for name in list(eng.jobs):
            if (
                eng.jobs[name] is None
                and not ctl.is_departing(name)
                and self.next_release[name] <= now + _EPS
            ):
                self._release_one(name)

    def _heap_entry_live(self, t: float, name: str) -> bool:
        # an entry is live iff it matches the current schedule and the
        # member is idle and not departing — anything else is a leftover
        # from a superseded push (re-admission, completed release) and is
        # dropped; a dropped entry can never become live again (departure
        # is final for a name's residency, re-admission re-pushes)
        return (
            self.next_release.get(name) == t
            and self.engine.jobs.get(name, False) is None
            and not self.controller.is_departing(name)
        )

    def release_jobs_fast(self, now: float) -> None:
        heap = self._release_heap
        due = []
        while heap and heap[0][0] <= now + _EPS:
            t, s, name = heapq.heappop(heap)
            if self._heap_entry_live(t, name):
                due.append((s, name))
        # membership-sequence order == jobs dict-insertion order == the
        # order the scan-based path releases (and draws RNG for)
        # same-time jobs
        due.sort()
        for _, name in due:
            self._release_one(name)

    def arbitration_order(self) -> list:
        prio = {n: i for i, n in enumerate(self.controller.order())}
        return sorted(self.engine.jobs, key=lambda n: prio.get(n, len(prio)))

    def resource_groups(self) -> list:
        return [None]

    def sort_group(self, group, keys: list) -> list:
        prio = {n: i for i, n in enumerate(self.controller.order())}
        keys.sort(key=lambda n: prio.get(n, len(prio)))
        return keys

    def next_external_time(self, now: float) -> float:
        t = math.inf
        for name, job in self.engine.jobs.items():
            if job is None and not self.controller.is_departing(name):
                t = min(t, self.next_release[name])
        if self.ev_idx < len(self.pending):
            t = min(t, self.pending[self.ev_idx].time)
        return t

    def next_external_time_fast(self, now: float) -> float:
        t = math.inf
        heap = self._release_heap
        while heap:
            tt, s, name = heap[0]
            if self._heap_entry_live(tt, name):
                t = tt
                break
            heapq.heappop(heap)
        if self.ev_idx < len(self.pending):
            t = min(t, self.pending[self.ev_idx].time)
        return t

    def on_job_complete(self, key, job, now, response) -> None:
        eng = self.engine
        self.responses[key].append(response)
        self.bounds[key].append(job.bound)
        self.jobs_done[key] += 1
        deadline = job.deadline_abs - job.release
        eng.record("complete", key, response=response, bound=job.bound)
        if response > deadline + 1e-6:
            self.misses[key] += 1
            eng.record("miss", key, overshoot=response - deadline)
        eng.jobs[key] = None
        self._finish_boundary(key, now)    # reclaim / commit staged changes
        if key in eng.jobs:                # still resident: next sporadic gap
            task = self.controller.task(key)
            gap = (
                float(self.rng.uniform(0, 0.2 * task.period))
                if self.release_jitter else 0.0
            )
            self.next_release[key] = max(job.release + task.period + gap, now)
            heapq.heappush(self._release_heap,
                           (self.next_release[key], self._mseq[key], key))


def simulate_churn(
    events: Sequence[ChurnEvent],
    gn_total: int,
    horizon: float,
    seed: int = 0,
    release_jitter: bool = True,
    worst_case: bool = False,
    tightened: bool = True,
    allow_realloc: bool = True,
    controller: Optional[DynamicController] = None,
    trace: Optional[EventTrace] = None,
    preemption: str = "none",
    gpu_ctx_overhead: float = 0.0,
    monitor=None,
    engine_variant: Optional[str] = None,
) -> ChurnSimResult:
    """Execute an admit/release churn trace under the online scheduler.

    ``preemption``/``gpu_ctx_overhead`` select the GPU arbitration model
    for the default controller; the engine always executes whatever
    arbitration the (possibly caller-provided) controller certified.

    ``monitor`` (a :class:`repro.obs.BoundMonitor`) is attached to the
    run's event trace — an internal one is created when ``trace`` is not
    given — and observes every scheduler/engine event live, tracking
    observed R against certified R̂ per task.  Attaching never alters the
    trace or the simulation."""
    if monitor is not None:
        if trace is None:
            trace = EventTrace()
        monitor.attach(trace)
    if controller is None:
        controller = DynamicController(
            gn_total,
            tightened=tightened,
            transition="boundary",
            allow_realloc=allow_realloc,
            trace=trace,
            preemption=preemption,
            gpu_ctx_overhead=gpu_ctx_overhead,
        )
    if controller.transition != "boundary":
        # an instant controller reclaims mid-job, leaving the engine's
        # membership pointing at entries the controller no longer knows
        raise ValueError(
            "simulate_churn requires a boundary-transition controller "
            f"(got transition={controller.transition!r})"
        )
    policy = _ChurnPolicy(
        events, controller, np.random.default_rng(seed), release_jitter,
        worst_case,
    )
    DiscreteEventEngine(policy, trace=trace,
                        variant=engine_variant).run(horizon)
    return ChurnSimResult(
        responses=policy.responses,
        bounds=policy.bounds,
        misses=policy.misses,
        jobs=policy.jobs_done,
        admitted=policy.admitted,
        rejected=policy.rejected,
    )


# ---- multi-host executor (federated broker validation) -----------------------


@dataclasses.dataclass
class FleetSimResult(ChurnSimResult):
    """Per-service outcome of a multi-host churn run.

    Extends :class:`ChurnSimResult` with fleet observables: the host each
    service was placed on at admission, and every completed
    departure-imbalance migration (``{"name", "src", "dst", "t"}``).  The
    validation invariant is unchanged — ``observed ≤ bound`` for every
    job, on whichever host it ran — plus: a migrating task's jobs must
    never miss while its residency spans two hosts."""

    placements: dict[str, int]
    migrations: list[dict]
    n_hosts: int
    # elastic fleet events applied during the run, in order:
    # {"kind": "add"|"retire", "host": h, "t": t, "ok": bool}
    fleet_events: list[dict] = dataclasses.field(default_factory=list)


class _FleetChurnPolicy(SchedulingPolicy):
    """Broker-routed dynamic membership across N host resource lanes.

    Member keys are ``(host, name)``; :meth:`resource_group` maps each to
    its host lane, so every host arbitrates its own CPU and copy bus while
    the single lockstep event loop keeps global time (and therefore
    broker-admission / migration causality) exact.  Jobs sample durations
    with the slice count committed *on the host they run on*; a migration
    moves the member key — and its sporadic release schedule — from the
    source lane to the target lane at the source job boundary.

    Incremental seam: one group per host; a capacity listener on every
    host controller (including elastically joined ones) invalidates that
    host's cached priority sort on any committed mutation, so migrations
    and rate changes dirty exactly the lanes they touch.  Pending
    releases live in one fleet-wide lazily-invalidated heap of
    ``(time, membership_seq, (host, name))``."""

    horizon_slack = _EPS
    incremental = True

    def __init__(
        self,
        events: Sequence[ChurnEvent],
        broker: CapacityBroker,
        rng: np.random.Generator,
        release_jitter: bool,
        worst_case: bool,
        elastic: Sequence[tuple] = (),
    ):
        self.broker = broker
        self.rng = rng
        self.release_jitter = release_jitter
        self.worst_case = worst_case
        self.pending = sorted(events, key=lambda e: (e.time, e.name))
        self.ev_idx = 0
        # elastic fleet schedule: (t, "add", gn_total[, speed]) grows the
        # fleet, (t, "retire", host) drains-then-retires; merged with the
        # churn stream in global time order (fleet ops first on ties)
        self.fleet_pending = sorted(elastic, key=lambda e: e[0])
        self.fl_idx = 0
        self.fleet_log: list[dict] = []
        self.next_release: dict[tuple, float] = {}
        self._release_heap: list = []
        self._mseq: dict[tuple, int] = {}
        self._seq = 0
        self.responses: dict[str, list[float]] = {}
        self.bounds: dict[str, list[float]] = {}
        self.misses: dict[str, int] = {}
        self.jobs_done: dict[str, int] = {}
        self.admitted: list[str] = []
        self.rejected: list[str] = []
        self.placements: dict[str, int] = {}

    # ---- engine hooks -------------------------------------------------------

    def bind(self, engine: DiscreteEventEngine) -> None:
        super().bind(engine)
        for h in range(len(self.broker.hosts)):
            self._listen_host(h)

    def _listen_host(self, h: int) -> None:
        # any committed mutation on host h (admit, reclaim, boundary
        # commit, rate change — including migration legs) can reshuffle
        # that lane's deadline-sorted priority order
        self.broker.hosts[h].add_capacity_listener(
            lambda h=h: self.order_changed(h)
        )

    def resource_group(self, key):
        return key[0]

    def resource_groups(self) -> list:
        return list(range(len(self.broker.hosts)))

    def sort_group(self, h, keys: list) -> list:
        prio = {n: i for i, n in enumerate(self.broker.hosts[h].order())}
        keys.sort(key=lambda k: prio.get(k[1], len(prio)))
        return keys

    def _track_member(self, key: tuple) -> None:
        self._mseq[key] = self._seq
        self._seq += 1
        self.membership_changed(key, added=True)

    def _untrack_member(self, key: tuple) -> None:
        self._mseq.pop(key, None)
        self.membership_changed(key, added=False)

    def display_name(self, key) -> str:
        return key[1]

    def event_meta(self, key) -> dict:
        return {"host": key[0]}

    def gpu_arbitration(self) -> tuple[str, float]:
        # simulate_fleet validates that every host certifies one model
        pm = self.broker.hosts[0].preemption
        return (pm.mode, pm.ctx)

    # ---- bookkeeping --------------------------------------------------------

    def _lift_bounds(self, hosts=None) -> None:
        """Raise in-flight jobs' bounds to their host's current R̂.

        An admission or an in-migration changes a host's interference; the
        new epoch's bound is certified over the transitional set, so it
        covers jobs of either epoch — lifting keeps the per-job validation
        sound for jobs spanning the reconfiguration.

        ``hosts`` narrows the lift to lanes whose certification actually
        changed: admission is per-host transactional (the losing hosts'
        state is untouched), so the admit path passes the one winning
        host and the lift stays O(that host's residents) — without it,
        filling a fleet to N residents costs O(N²) lifts.  ``None``
        (reclaims, retires) lifts fleet-wide, since drain migrations can
        cascade across lanes.  Either way ``max`` makes unaffected lanes
        a no-op, so the narrowed lift is byte-identical."""
        jobs = self.engine.jobs
        if hosts is None:
            for (h, name), job in jobs.items():
                if job is not None:
                    job.bound = max(job.bound,
                                    self.broker.hosts[h].bound(name))
            return
        for h in hosts:
            for name, b in self.broker.hosts[h].bounds().items():
                job = jobs.get((h, name))
                if job is not None and b > job.bound:
                    job.bound = b

    def _boundary(self, name: str, now: float) -> str:
        """Job boundary on ``name``'s active host: reclaim a departer,
        complete a migration (moving the member to its target lane), or
        commit staged changes."""
        h = self.broker.active_host(name)
        if h is None:
            return "none"
        key = (h, name)
        res = self.broker.job_boundary(name, t=now)
        if res == "reclaimed":
            self.engine.jobs.pop(key, None)
            self.next_release.pop(key, None)
            self._untrack_member(key)
            # the departure may have started migrations; an idle source
            # is at its boundary NOW (mirrors the idle-departer reclaim)
            self._drain_idle_migrations(now)
            self._lift_bounds()
        elif res == "migrated":
            nr = self.next_release.pop(key, now)
            self.engine.jobs.pop(key, None)
            self._untrack_member(key)
            dst = self.broker.active_host(name)
            self.engine.jobs[(dst, name)] = None
            self.next_release[(dst, name)] = max(nr, now)
            self._track_member((dst, name))
            heapq.heappush(
                self._release_heap,
                (self.next_release[(dst, name)],
                 self._mseq[(dst, name)], (dst, name)),
            )
        return res

    def _drain_idle_migrations(self, now: float) -> None:
        progress = True
        while progress:
            progress = False
            for name, mig in list(self.broker.migrating.items()):
                key = (mig.src, name)
                if key in self.engine.jobs and self.engine.jobs[key] is None:
                    self._boundary(name, now)
                    progress = True

    def begin_step(self, now: float) -> None:
        # merge the churn and elastic streams in global time order so a
        # retire at t precedes (and its drain migrations can absorb) an
        # arrival at t' > t even when the engine wakes once for both
        while True:
            ct = (
                self.pending[self.ev_idx].time
                if self.ev_idx < len(self.pending) else math.inf
            )
            ft = (
                self.fleet_pending[self.fl_idx][0]
                if self.fl_idx < len(self.fleet_pending) else math.inf
            )
            if min(ct, ft) > now + _EPS:
                break
            if ft <= ct:
                fe = self.fleet_pending[self.fl_idx]
                self.fl_idx += 1
                self._apply_fleet_event(fe, now)
            else:
                ev = self.pending[self.ev_idx]
                self.ev_idx += 1
                self._apply_churn_event(ev, now)

    def _apply_fleet_event(self, fe: tuple, now: float) -> None:
        kind = fe[1]
        if kind == "add":
            speed = float(fe[3]) if len(fe) > 3 else 1.0
            h = self.broker.add_host(
                gn_total=int(fe[2]), speed=speed, t=now
            )
            self._listen_host(h)
            # a new resource group: the engine's group index must grow
            self.order_changed()
            self.fleet_log.append(
                {"kind": "add", "host": h, "t": now, "ok": True}
            )
        elif kind == "retire":
            h = int(fe[2])
            ok = self.broker.retire_host(h, t=now)
            self.fleet_log.append(
                {"kind": "retire", "host": h, "t": now, "ok": ok}
            )
            if ok:
                # drain migrations off idle members complete at their
                # (immediate) job boundary; busy members at their next
                self._drain_idle_migrations(now)
                self._lift_bounds()
        else:
            raise ValueError(f"unknown fleet event kind {kind!r}")

    def _apply_churn_event(self, ev: ChurnEvent, now: float) -> None:
        eng = self.engine
        if ev.kind == "admit":
            dec = self.broker.admit(ev.task, t=now)
            if dec.admitted:
                h = dec.host
                self.admitted.append(ev.name)
                self.placements[ev.name] = h
                eng.jobs[(h, ev.name)] = None
                self.next_release[(h, ev.name)] = now
                self._track_member((h, ev.name))
                heapq.heappush(
                    self._release_heap,
                    (now, self._mseq[(h, ev.name)], (h, ev.name)),
                )
                # setdefault: a re-admission of a departed name must
                # extend its history, not erase the first residency
                self.responses.setdefault(ev.name, [])
                self.bounds.setdefault(ev.name, [])
                self.misses.setdefault(ev.name, 0)
                self.jobs_done.setdefault(ev.name, 0)
                self._lift_bounds(hosts=(h,))
            else:
                self.rejected.append(ev.name)
        elif ev.kind == "release":
            h = self.broker.active_host(ev.name)
            if self.broker.release(ev.name, t=now):
                if eng.jobs.get((h, ev.name)) is None:
                    self._boundary(ev.name, now)   # idle: reclaim now
                self._drain_idle_migrations(now)
                self._lift_bounds()
        else:
            raise ValueError(f"unknown churn event kind {ev.kind!r}")

    def _release_one(self, key: tuple) -> None:
        h, name = key
        ctl = self.broker.hosts[h]
        task = ctl.task(name)
        self.engine.start_job(key, EngineJob(
            release=self.next_release[key],
            deadline_abs=self.next_release[key] + task.deadline,
            chain=task.chain(),
            durations=_sample_durations(
                task, 2 * ctl.allocation[name], self.rng,
                self.worst_case,
            ),
            bound=ctl.bound(name),
        ))

    def release_jobs(self, now: float) -> None:
        eng = self.engine
        for key in list(eng.jobs):
            h, name = key
            ctl = self.broker.hosts[h]
            if (
                eng.jobs[key] is None
                and not ctl.is_departing(name)
                and self.next_release.get(key, math.inf) <= now + _EPS
            ):
                self._release_one(key)

    def _heap_entry_live(self, t: float, key: tuple) -> bool:
        # mirror of the scan-based release/next-external predicate; stale
        # entries (superseded by a migration, a departure, or a completed
        # release) are dropped — a migration or re-admission pushes a
        # fresh entry under the new key, so nothing is lost
        h, name = key
        return (
            self.next_release.get(key) == t
            and self.engine.jobs.get(key, False) is None
            and not self.broker.hosts[h].is_departing(name)
        )

    def release_jobs_fast(self, now: float) -> None:
        heap = self._release_heap
        due = []
        while heap and heap[0][0] <= now + _EPS:
            t, s, key = heapq.heappop(heap)
            if self._heap_entry_live(t, key):
                due.append((s, key))
        # membership-sequence order == jobs dict-insertion order == the
        # scan-based release (and RNG draw) order for same-time jobs
        due.sort()
        for _, key in due:
            self._release_one(key)

    def arbitration_order(self) -> list:
        out = []
        for h, ctl in enumerate(self.broker.hosts):
            prio = {n: i for i, n in enumerate(ctl.order())}
            members = [k for k in self.engine.jobs if k[0] == h]
            members.sort(key=lambda k: prio.get(k[1], len(prio)))
            out.extend(members)
        return out

    def next_external_time(self, now: float) -> float:
        t = math.inf
        for key, job in self.engine.jobs.items():
            h, name = key
            if job is None and not self.broker.hosts[h].is_departing(name):
                t = min(t, self.next_release.get(key, math.inf))
        if self.ev_idx < len(self.pending):
            t = min(t, self.pending[self.ev_idx].time)
        if self.fl_idx < len(self.fleet_pending):
            t = min(t, self.fleet_pending[self.fl_idx][0])
        return t

    def next_external_time_fast(self, now: float) -> float:
        t = math.inf
        heap = self._release_heap
        while heap:
            tt, s, key = heap[0]
            if self._heap_entry_live(tt, key):
                t = tt
                break
            heapq.heappop(heap)
        if self.ev_idx < len(self.pending):
            t = min(t, self.pending[self.ev_idx].time)
        if self.fl_idx < len(self.fleet_pending):
            t = min(t, self.fleet_pending[self.fl_idx][0])
        return t

    def on_job_complete(self, key, job, now, response) -> None:
        eng = self.engine
        h, name = key
        self.responses[name].append(response)
        self.bounds[name].append(job.bound)
        self.jobs_done[name] += 1
        deadline = job.deadline_abs - job.release
        eng.record("complete", key, response=response, bound=job.bound)
        if response > deadline + 1e-6:
            self.misses[name] += 1
            eng.record("miss", key, overshoot=response - deadline)
        eng.jobs[key] = None
        self._boundary(name, now)   # reclaim / migrate / commit staged
        h2 = self.broker.active_host(name)
        if h2 is not None and (h2, name) in eng.jobs:
            # still a fleet member (possibly on a new host): next sporadic
            # release, with the post-boundary committed parameters
            task = self.broker.hosts[h2].task(name)
            gap = (
                float(self.rng.uniform(0, 0.2 * task.period))
                if self.release_jitter else 0.0
            )
            self.next_release[(h2, name)] = max(
                job.release + task.period + gap, now
            )
            heapq.heappush(
                self._release_heap,
                (self.next_release[(h2, name)],
                 self._mseq[(h2, name)], (h2, name)),
            )


def simulate_fleet(
    events: Sequence[ChurnEvent],
    n_hosts: int,
    gn_per_host: int,
    horizon: float,
    seed: int = 0,
    release_jitter: bool = True,
    worst_case: bool = False,
    tightened: bool = True,
    placement: str = "least_loaded",
    imbalance_threshold: float = 0.25,
    max_migrations_per_event: int = 1,
    engine: str = "batch",
    broker: Optional[CapacityBroker] = None,
    trace: Optional[EventTrace] = None,
    preemption: str = "none",
    gpu_ctx_overhead: float = 0.0,
    host_speeds: Optional[Sequence[float]] = None,
    monitor=None,
    elastic: Sequence[tuple] = (),
    engine_variant: Optional[str] = None,
) -> FleetSimResult:
    """Execute a churn trace across ``n_hosts`` broker-routed hosts.

    ``monitor`` behaves as in :func:`simulate_churn`: attached to the
    run's event trace (created internally when ``trace`` is not given)
    to track observed R vs certified R̂ without touching the trace.

    ``elastic`` is an optional fleet schedule merged with the churn
    stream in global time order: ``(t, "add", gn_total[, speed])`` joins
    a host mid-run (mirroring host 0's configuration);
    ``(t, "retire", h)`` drains host ``h`` through certified migrations
    and retires it once empty.  A retire that cannot place every
    resident elsewhere is refused and logged
    (``result.fleet_events[..]["ok"] is False``) — the fleet keeps
    running on the undrained host."""
    if monitor is not None:
        if trace is None:
            trace = EventTrace()
        monitor.attach(trace)
    if broker is None:
        broker = CapacityBroker.build(
            n_hosts, gn_per_host,
            trace=trace,
            transition="boundary",
            engine=engine,
            tightened=tightened,
            placement=placement,
            imbalance_threshold=imbalance_threshold,
            max_migrations_per_event=max_migrations_per_event,
            preemption=preemption,
            gpu_ctx_overhead=gpu_ctx_overhead,
            host_speeds=host_speeds,
        )
    for h, ctl in enumerate(broker.hosts):
        if ctl.transition != "boundary":
            # an instant controller reclaims mid-job, leaving the engine's
            # membership pointing at entries the controller no longer knows
            raise ValueError(
                "simulate_fleet requires boundary-transition hosts "
                f"(host {h} has transition={ctl.transition!r})"
            )
        if ctl.preemption != broker.hosts[0].preemption:
            # one engine-wide arbitration model: mixed fleets would need
            # per-lane arbitration configs the lockstep loop doesn't carry
            raise ValueError(
                "simulate_fleet requires one GPU arbitration model across "
                f"hosts (host {h} has {ctl.preemption}, host 0 has "
                f"{broker.hosts[0].preemption})"
            )
    policy = _FleetChurnPolicy(
        events, broker, np.random.default_rng(seed), release_jitter,
        worst_case, elastic=elastic,
    )
    DiscreteEventEngine(policy, trace=trace,
                        variant=engine_variant).run(horizon)
    return FleetSimResult(
        responses=policy.responses,
        bounds=policy.bounds,
        misses=policy.misses,
        jobs=policy.jobs_done,
        admitted=policy.admitted,
        rejected=policy.rejected,
        placements=policy.placements,
        migrations=[
            {"name": m.name, "src": m.src, "dst": m.dst, "t": m.started}
            for m in broker.migration_log
        ],
        n_hosts=len(broker.hosts),
        fleet_events=policy.fleet_log,
    )
