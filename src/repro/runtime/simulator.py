"""Discrete-event executor for RT CPU–bus–accelerator task sets.

This is the container-side stand-in for the paper's real-GPU experiment
(Figs. 12–13): it *executes* task sets under the RTGPU runtime rules —

  * CPU: preemptive fixed-priority (one core),
  * bus: non-preemptive fixed-priority (one PCIe-like channel),
  * accelerator: federated — every task owns 2·GN_i dedicated virtual SMs
    (chip-slice interleave lanes), so GPU segments start immediately after
    their copy-in completes (no contention by construction),

with per-job segment durations sampled from [lo, hi] (worst-case model:
lo == hi).  Observed response times validate the analysis bounds:
tests assert  observed R ≤ analytic R̂  for admitted sets.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Optional

import numpy as np

from repro.core import RTTask, SegmentKind, TaskSet

__all__ = ["SimResult", "simulate"]

_EPS = 1e-9


@dataclasses.dataclass
class SimResult:
    responses: list[list[float]]          # per task, per completed job
    misses: list[int]                     # per task deadline misses
    jobs: list[int]                       # per task completed jobs

    @property
    def any_miss(self) -> bool:
        return any(m > 0 for m in self.misses)

    def max_response(self, i: int) -> float:
        return max(self.responses[i]) if self.responses[i] else 0.0


@dataclasses.dataclass
class _Job:
    task_id: int
    release: float
    deadline_abs: float
    seg_idx: int = 0
    remaining: float = 0.0          # remaining time of the current segment
    durations: Optional[list] = None
    done: bool = False


def _sample_durations(
    task: RTTask, alloc_vsm: int, rng, worst_case: bool = False
) -> list[float]:
    """One duration per chain segment, honoring [lo, hi] bounds and
    Lemma 5.1 for accelerator segments.  ``worst_case`` pins every segment
    to its upper bound (the Fig. 12 WCET execution model)."""
    out = []
    for kind, idx in task.chain():
        if kind is SegmentKind.CPU:
            lo, hi = task.cpu_lo[idx], task.cpu_hi[idx]
        elif kind is SegmentKind.MEM:
            lo, hi = task.mem_lo[idx], task.mem_hi[idx]
        else:
            lo, hi = task.gpu[idx].response_bounds(alloc_vsm)
        if worst_case or hi <= lo:
            out.append(hi)
        else:
            out.append(float(rng.uniform(lo, hi)))
    return out


def simulate(
    taskset: TaskSet,
    alloc: list[int],
    horizon: float,
    seed: int = 0,
    release_jitter: bool = True,
    worst_case: bool = False,
) -> SimResult:
    """Run the federated RT executor for ``horizon`` time units.

    Priority = taskset order (0 highest).  Sporadic releases: period T_i
    plus optional random inter-arrival slack (sporadic ≥ T)."""
    n = len(taskset)
    rng = np.random.default_rng(seed)
    chains = [t.chain() for t in taskset]

    releases: list[float] = []
    for i, t in enumerate(taskset):
        releases.append(float(rng.uniform(0, t.period)) if release_jitter else 0.0)

    jobs: list[Optional[_Job]] = [None] * n  # at most one active job per task
    responses: list[list[float]] = [[] for _ in range(n)]
    misses = [0] * n
    completed = [0] * n

    now = 0.0
    bus_running: Optional[int] = None  # task id holding the bus (non-preempt)

    def seg_kind(i: int) -> Optional[SegmentKind]:
        j = jobs[i]
        if j is None or j.done:
            return None
        return chains[i][j.seg_idx][0]

    while now < horizon:
        # release new jobs
        for i, t in enumerate(taskset):
            if jobs[i] is None and releases[i] <= now + _EPS:
                j = _Job(
                    task_id=i,
                    release=releases[i],
                    deadline_abs=releases[i] + t.deadline,
                    durations=_sample_durations(t, 2 * alloc[i], rng, worst_case),
                )
                j.remaining = j.durations[0]
                jobs[i] = j

        # pick CPU owner: highest-priority ready CPU segment (preemptive)
        cpu_owner = next(
            (i for i in range(n) if seg_kind(i) is SegmentKind.CPU), None
        )
        # bus owner: keep non-preemptive holder; else highest-priority waiter
        if bus_running is not None and seg_kind(bus_running) is not SegmentKind.MEM:
            bus_running = None
        if bus_running is None:
            bus_running = next(
                (i for i in range(n) if seg_kind(i) is SegmentKind.MEM), None
            )

        # running set: cpu owner, bus owner, every GPU segment (dedicated)
        running = set()
        if cpu_owner is not None:
            running.add(cpu_owner)
        if bus_running is not None:
            running.add(bus_running)
        for i in range(n):
            if seg_kind(i) is SegmentKind.GPU:
                running.add(i)

        # next event time: earliest completion or next release
        dt = math.inf
        for i in running:
            dt = min(dt, jobs[i].remaining)
        for i in range(n):
            if jobs[i] is None:
                dt = min(dt, releases[i] - now)
        if not math.isfinite(dt):
            break
        dt = max(dt, 0.0)
        step_end = min(now + dt, horizon)
        dt = step_end - now

        for i in running:
            jobs[i].remaining -= dt
        now = step_end

        # process completions
        for i in list(running):
            j = jobs[i]
            if j.remaining <= _EPS:
                if chains[i][j.seg_idx][0] is SegmentKind.MEM and bus_running == i:
                    bus_running = None
                j.seg_idx += 1
                if j.seg_idx >= len(chains[i]):
                    resp = now - j.release
                    responses[i].append(resp)
                    completed[i] += 1
                    if resp > taskset[i].deadline + 1e-6:
                        misses[i] += 1
                    # next sporadic release
                    gap = 0.0
                    if release_jitter:
                        gap = float(rng.uniform(0, 0.2 * taskset[i].period))
                    releases[i] = j.release + taskset[i].period + gap
                    if releases[i] < now:
                        releases[i] = now
                    jobs[i] = None
                else:
                    j.remaining = j.durations[j.seg_idx]
    return SimResult(responses=responses, misses=misses, jobs=completed)
