"""Golden-trace corpus recorder: ``python -m repro.runtime.record_golden``.

Runs every :data:`repro.core.GOLDEN_SCENARIOS` preset through the
discrete-event simulator and serializes the complete observable outcome —
RNG seed and scenario parameters, allocation, per-job responses/misses,
and the full :class:`~repro.sched.EventTrace` — to one JSON file per
scenario under ``tests/golden/``.

``tests/test_golden_traces.py`` replays each file and asserts event-by-
event equality, so the corpus pins the scheduler's observable behavior:
any change to arbitration, RNG call order, or trace emission fails CI with
the first divergent event.  Regenerating the corpus is therefore a
*deliberate* act — run this CLI and review the diff:

    PYTHONPATH=src python -m repro.runtime.record_golden            # all
    PYTHONPATH=src python -m repro.runtime.record_golden --only steady
    PYTHONPATH=src python -m repro.runtime.record_golden --check    # no write
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Optional, Sequence

from repro.core import GOLDEN_SCENARIOS, ScenarioPreset
from repro.sched import EventTrace

from .simulator import simulate, simulate_churn, simulate_fleet

__all__ = ["GOLDEN_FORMAT", "preset_params", "record_scenario", "dump_doc",
           "main"]

#: bump when the golden-file schema changes (forces a deliberate re-record)
GOLDEN_FORMAT = 1

DEFAULT_OUT = os.path.join("tests", "golden")


def preset_params(preset: ScenarioPreset) -> dict:
    """JSON-normalized preset parameters (tuples become lists), stored in
    each golden file so the replay harness can detect preset drift.

    Only behavior-bearing fields: ``name``/``kind`` are stored separately,
    ``description`` is cosmetic (rewording it must not invalidate a
    recorded golden file), and fields the preset's kind never reads
    (``churn``/``churn_horizon`` for static scenarios, the task-set knobs
    for churn/fleet ones, the fleet knobs for single-host kinds) are
    dropped so unrelated default changes don't spuriously demand
    re-recording."""
    params = dataclasses.asdict(preset)
    fleet_fields = ("n_hosts", "placement", "imbalance_threshold")
    if preset.kind == "static":
        irrelevant = ("churn", "churn_horizon") + fleet_fields
    elif preset.kind == "churn":
        irrelevant = ("total_util", "config") + fleet_fields
    else:                                  # fleet
        irrelevant = ("total_util", "config")
    for field in ("name", "kind", "description") + irrelevant:
        params.pop(field, None)
    if preset.preemption == "none":
        # the inert default: dedicated-slice presets recorded before the
        # arbitration seam existed stay valid without re-recording (the
        # ctx overhead is read only under "priority")
        params.pop("preemption", None)
        params.pop("gpu_ctx_overhead", None)
    return json.loads(json.dumps(params))


def record_scenario(preset: ScenarioPreset) -> dict:
    """One corpus entry: run the preset and capture every observable."""
    trace = EventTrace(label=f"golden:{preset.name}")
    doc: dict = {
        "format": GOLDEN_FORMAT,
        "scenario": preset.name,
        "kind": preset.kind,
        "description": preset.description,
        "params": preset_params(preset),
    }
    if preset.kind == "static":
        ts, alloc = preset.build_static()
        res = simulate(
            ts, alloc, preset.horizon, seed=preset.seed,
            release_jitter=preset.release_jitter,
            worst_case=preset.worst_case, trace=trace,
            preemption=preset.preemption,
            gpu_ctx_overhead=preset.gpu_ctx_overhead,
        )
        doc["alloc"] = alloc
        doc["result"] = {
            "responses": res.responses,
            "misses": res.misses,
            "jobs": res.jobs,
        }
    elif preset.kind == "churn":
        events = preset.build_churn()
        res = simulate_churn(
            events, preset.gn_total, preset.horizon, seed=preset.seed,
            release_jitter=preset.release_jitter,
            worst_case=preset.worst_case, trace=trace,
            preemption=preset.preemption,
            gpu_ctx_overhead=preset.gpu_ctx_overhead,
        )
        doc["result"] = {
            "responses": res.responses,
            "bounds": res.bounds,
            "misses": res.misses,
            "jobs": res.jobs,
            "admitted": res.admitted,
            "rejected": res.rejected,
        }
    else:                                  # fleet
        events = preset.build_churn()
        res = simulate_fleet(
            events, preset.n_hosts, preset.gn_total, preset.horizon,
            seed=preset.seed, release_jitter=preset.release_jitter,
            worst_case=preset.worst_case, placement=preset.placement,
            imbalance_threshold=preset.imbalance_threshold, trace=trace,
            preemption=preset.preemption,
            gpu_ctx_overhead=preset.gpu_ctx_overhead,
        )
        doc["result"] = {
            "responses": res.responses,
            "bounds": res.bounds,
            "misses": res.misses,
            "jobs": res.jobs,
            "admitted": res.admitted,
            "rejected": res.rejected,
            "placements": res.placements,
            "migrations": res.migrations,
            "n_hosts": res.n_hosts,
        }
    doc["trace"] = trace.to_json()
    return doc


def dump_doc(doc: dict) -> str:
    """Canonical golden-file text: sorted keys, one-space indent — stable
    bytes for identical runs, reviewable line diffs for intentional ones."""
    return json.dumps(doc, sort_keys=True, indent=1, separators=(",", ": "))


def _summarize(doc: dict) -> str:
    result = doc["result"]
    if doc["kind"] == "static":
        jobs = sum(result["jobs"])
        misses = sum(result["misses"])
    else:
        jobs = sum(result["jobs"].values())
        misses = sum(result["misses"].values())
    return (f"{doc['scenario']:20s} {doc['kind']:6s} "
            f"events={len(doc['trace']['events']):5d} jobs={jobs:4d} "
            f"misses={misses}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.runtime.record_golden",
        description="(Re)generate the golden-trace regression corpus.",
    )
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help=f"output directory (default: {DEFAULT_OUT})")
    ap.add_argument("--only", action="append", default=None, metavar="NAME",
                    help="record only the named scenario (repeatable)")
    ap.add_argument("--check", action="store_true",
                    help="re-run scenarios and diff against existing files "
                         "instead of writing (exit 1 on divergence)")
    args = ap.parse_args(argv)

    presets = GOLDEN_SCENARIOS
    if args.only:
        unknown = set(args.only) - {p.name for p in presets}
        if unknown:
            ap.error(f"unknown scenario(s): {sorted(unknown)}")
        presets = tuple(p for p in presets if p.name in set(args.only))

    os.makedirs(args.out, exist_ok=True)
    divergent = []
    for preset in presets:
        doc = record_scenario(preset)
        path = os.path.join(args.out, f"{preset.name}.json")
        text = dump_doc(doc)
        if args.check:
            try:
                with open(path) as fh:
                    stored = fh.read()
            except FileNotFoundError:
                stored = None
            status = "ok" if stored == text + "\n" else "DIVERGED"
            if status != "ok":
                divergent.append(preset.name)
            print(f"{_summarize(doc)}  [{status}]")
        else:
            with open(path, "w") as fh:
                fh.write(text + "\n")
            print(f"{_summarize(doc)}  -> {path}")
    if args.check and divergent:
        print(f"divergent scenarios: {divergent}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
