"""Quickstart: the RTGPU scheduler end to end in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. Generate a Table-1 synthetic task set.
2. Run Algorithm 2 (grid-searched federated scheduling) + Theorem 5.6.
3. Compare against the STGM and self-suspension baselines.
4. Execute the admitted set on the discrete-event federated runtime and
   check the analytic bounds hold.
"""
import numpy as np

from repro.core import (
    GeneratorConfig,
    analyze_rtgpu_plus,
    analyze_self_suspension,
    analyze_stgm,
    generate_taskset,
    schedule,
)
from repro.runtime import simulate


def main():
    rng = np.random.default_rng(7)
    taskset = generate_taskset(rng, total_util=0.7, config=GeneratorConfig())
    print("task set (deadline-monotonic priorities):")
    for t in taskset:
        print(
            f"  {t.name}: m={t.m} CPU segs, {t.n_mem} copies, {t.n_gpu} kernels,"
            f" D=T={t.deadline:.1f} ms"
        )

    gn = 10  # physical SMs / chip-slices -> 20 virtual SMs
    res = schedule(taskset, gn)  # paper-faithful Theorem 5.6 + Algorithm 2
    print(f"\nRTGPU (paper):   schedulable={res.schedulable} alloc={res.alloc}")
    res_plus = schedule(taskset, gn, analyzer=analyze_rtgpu_plus)
    print(f"RTGPU+ (ours):   schedulable={res_plus.schedulable} alloc={res_plus.alloc}")
    res_ss = schedule(taskset, gn, analyzer=analyze_self_suspension, mode="greedy")
    print(f"self-suspension: schedulable={res_ss.schedulable}")
    res_stgm = schedule(taskset, gn, analyzer=analyze_stgm, mode="greedy")
    print(f"STGM busy-wait:  schedulable={res_stgm.schedulable}")

    best = res_plus if res_plus.schedulable else res
    if not best.schedulable:
        print("\nset not admitted; try lower utilization")
        return
    print("\nexecuting on the federated discrete-event runtime ...")
    sim = simulate(taskset, list(best.alloc), horizon=30 * max(t.period for t in taskset))
    for i, ta in enumerate(best.analysis.tasks):
        obs = sim.max_response(i)
        print(
            f"  {ta.name}: analytic R̂={ta.response:8.2f}  observed max R={obs:8.2f}"
            f"  (bound {'OK' if obs <= ta.response + 1e-6 else 'VIOLATED'})"
            f"  misses={sim.misses[i]}"
        )
    assert not sim.any_miss
    print("no deadline misses — analysis bound validated.")


if __name__ == "__main__":
    main()
