"""RT serving: admission-controlled multi-model inference (the paper's
use case — several AI tasks sharing one accelerator with hard deadlines).

  PYTHONPATH=src python examples/rt_serving.py

Three model services (reduced configs of assigned archs) ask for admission
with different periods/deadlines.  The controller sizes each service's
dedicated chip-slice allocation via Algorithm 2; admitted services then run
REAL prefill+decode steps through the serving engine while the discrete-
event runtime validates the timing model.
"""
import numpy as np

from repro.configs import get_smoke_config
from repro.runtime import AdmissionController, ServingTaskSpec, serving_task_to_rt, simulate
from repro.serving import ServeConfig, ServingEngine


def main():
    ac = AdmissionController(gn_total=12)

    services = [
        ServingTaskSpec(
            name="chat-qwen", arch_id="qwen3-0.6b", period_ms=50.0,
            deadline_ms=40.0, batch=4, seq_len=256, new_tokens=3,
            roofline_step_s=0.002, collective_s=2e-4, dominant="compute_s",
        ),
        ServingTaskSpec(
            name="vision-internvl", arch_id="internvl2-2b", period_ms=100.0,
            deadline_ms=80.0, batch=2, seq_len=512, new_tokens=2,
            roofline_step_s=0.004, collective_s=3e-4, dominant="memory_s",
        ),
        ServingTaskSpec(
            name="audio-whisper", arch_id="whisper-base", period_ms=200.0,
            deadline_ms=150.0, batch=2, seq_len=128, new_tokens=4,
            roofline_step_s=0.001, collective_s=1e-4, dominant="compute_s",
        ),
        ServingTaskSpec(  # an aggressive latecomer that should be rejected
            name="greedy-batch", arch_id="dbrx-132b", period_ms=8.0,
            deadline_ms=6.0, batch=64, seq_len=2048, new_tokens=4,
            roofline_step_s=0.050, collective_s=1e-3, dominant="compute_s",
        ),
    ]

    for spec in services:
        task = serving_task_to_rt(spec)
        dec = ac.admit(task)
        verdict = "ADMITTED" if dec.admitted else f"REJECTED ({dec.reason})"
        print(f"{spec.name:18s} T={spec.period_ms:6.1f}ms D={spec.deadline_ms:6.1f}ms -> {verdict}")
        if dec.admitted:
            print(f"{'':18s} slice allocation now: {dec.alloc}")

    ts = ac.current_taskset()
    sim = simulate(ts, ac.current_alloc_list(), horizon=5000.0, seed=0)
    print(f"\nruntime check over 5 s: misses={sim.misses} jobs={sim.jobs}")
    assert not sim.any_miss

    # run REAL decode steps for one admitted service
    cfg = get_smoke_config("qwen3-0.6b")
    engine = ServingEngine(cfg, ServeConfig(max_context=128, batch=4))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (4, 32)).astype(np.int32)
    out, stats = engine.generate(prompts, max_new_tokens=8)
    print(f"\nchat-qwen real decode: {out.shape[1]} tokens/slot, "
          f"prefill {stats['prefill_s']*1e3:.1f} ms, "
          f"decode {stats['decode_s_per_tok']*1e3:.1f} ms/tok")
    print("sampled ids:", out[0].tolist())


if __name__ == "__main__":
    main()
