"""Live churn demo: three model services joining and leaving a running
wall-clock executor, with the online scheduler doing admission and the
whole run dumped as a Chrome trace.

  PYTHONPATH=src python examples/rt_churn.py
  # then open results/rt_churn_trace.json in chrome://tracing or Perfetto

Timeline (wall clock, seconds):
  0.0   chat + vision admitted and running
  0.6   audio service asks to join (admitted against the transitional set)
  1.4   vision deregisters — slices reclaimed at its job boundary
  2.0   end; per-service stats + scheduler event counts printed

Job bodies are calibrated busy-loops standing in for jitted decode steps
(see examples/rt_serving.py for the real-engine variant) so the demo runs
anywhere in ~2 s; the admission decisions, mode-change protocol, and the
trace wiring are the real subsystem.
"""
import json
import os
import time

from repro.runtime import Service, ServingTaskSpec, WallClockExecutor, serving_task_to_rt
from repro.sched import DynamicController, EventTrace

OUT = "results/rt_churn_trace.json"


def busy_job(cost_s: float):
    def job():
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < cost_s:
            pass
    return job


def spec(name, arch, period_ms, deadline_ms, step_ms):
    return ServingTaskSpec(
        name=name, arch_id=arch, period_ms=period_ms, deadline_ms=deadline_ms,
        batch=2, seq_len=256, new_tokens=2,
        roofline_step_s=step_ms / 1000.0, collective_s=2e-4,
        dominant="compute_s",
    )


def main():
    trace = EventTrace(us_per_unit=1e6, label="rt_churn")  # wall clock in s
    controller = DynamicController(gn_total=8, trace=trace)

    specs = {
        "chat-qwen": spec("chat-qwen", "qwen3-0.6b", 50.0, 40.0, 2.0),
        "vision-internvl": spec("vision-internvl", "internvl2-2b", 100.0, 80.0, 4.0),
        "audio-whisper": spec("audio-whisper", "whisper-base", 150.0, 120.0, 1.5),
    }
    jobs = {"chat-qwen": 0.004, "vision-internvl": 0.008, "audio-whisper": 0.003}

    def admit(name, t=0.0):
        dec = controller.admit(serving_task_to_rt(specs[name]), t=t)
        verdict = "ADMITTED" if dec.admitted else f"REJECTED ({dec.reason})"
        print(f"[t={t:.1f}s] {name:16s} -> {verdict}"
              + (f"  alloc={dec.alloc}" if dec.admitted else ""))
        return dec.admitted

    def service(name):
        s = specs[name]
        return Service(name, period_s=s.period_ms / 1e3,
                       deadline_s=s.deadline_ms / 1e3, run_job=busy_job(jobs[name]))

    # initial residents
    initial = [service(n) for n in ("chat-qwen", "vision-internvl") if admit(n)]
    ex = WallClockExecutor(initial, trace=trace)

    def join_audio(executor):
        if admit("audio-whisper", t=0.6):
            executor.add_service(service("audio-whisper"))

    def leave_vision(executor):
        controller.release("vision-internvl", t=1.4)
        executor.remove_service("vision-internvl")
        controller.job_boundary("vision-internvl", t=1.4)
        print("[t=1.4s] vision-internvl departed; "
              f"free slices: {controller.free_capacity}/{controller.gn_total}")

    stats = ex.run(duration_s=2.0, events=[(0.6, join_audio), (1.4, leave_vision)])

    print("\nper-service stats:")
    for name, st in stats.items():
        print(f"  {name:16s} released={st['released']:3d} "
              f"completed={st['completed']:3d} missed={st['missed']:2d} "
              f"worst={st['worst_response_ms']:.1f} ms")
    print("scheduler events:", dict(sorted(trace.counts().items())))

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    trace.dump(OUT)
    n = len(trace)
    print(f"\nwrote {OUT} ({n} events) — open in chrome://tracing")
    with open(OUT) as fh:
        assert json.load(fh)["traceEvents"]


if __name__ == "__main__":
    main()
