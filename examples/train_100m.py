"""End-to-end training driver: a ~100M-param qwen3-family model for a few
hundred steps on synthetic bigram data (loss must drop).

  PYTHONPATH=src python examples/train_100m.py --steps 200

This is the full substrate working together: data pipeline -> pattern-
scanned model -> chunked loss -> AdamW -> checkpoint.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.data import DataConfig, TokenPipeline
from repro.models import LayerSpec, Model, ModelConfig
from repro.train.checkpoint import save_checkpoint
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def model_100m() -> ModelConfig:
    """~100M params: 12L d=768 (GPT-2-small-scale qwen3-style)."""
    return ModelConfig(
        name="qwen3-100m", arch_type="dense", d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=2048, vocab=8192,
        pattern=(LayerSpec("attn", "mlp"),), n_repeats=12,
        qk_norm=True, tie_embeddings=True, dtype="float32",
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args(argv)

    cfg = model_100m()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M")

    opt_cfg = AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    opt_state = init_opt_state(params)
    data = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch))

    @jax.jit
    def step_fn(params, opt_state, tokens, labels):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, tokens, labels)
        )(params)
        params, opt_state, metrics = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss, metrics

    losses = []
    for step, (tokens, labels) in enumerate(data):
        if step >= args.steps:
            break
        params, opt_state, loss, metrics = step_fn(
            params, opt_state, jnp.asarray(tokens), jnp.asarray(labels)
        )
        losses.append(float(loss))
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"lr {float(metrics['lr']):.2e}")
    first = sum(losses[:10]) / min(10, len(losses))
    last = sum(losses[-10:]) / min(10, len(losses))
    print(f"\nloss: first-10 {first:.4f} -> last-10 {last:.4f}")
    assert last < first, "training failed to reduce loss"
    save_checkpoint(args.ckpt_dir, args.steps, params)
    print(f"checkpoint saved -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()
